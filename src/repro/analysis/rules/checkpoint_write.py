"""nonatomic-checkpoint-write: checkpoint bytes move only via the store.

``checkpoint/store.py`` owns the tmp/rename publish protocol (write
``tmp.<step>`` → park final as ``stale`` → rename tmp into place →
drop stale) and the per-group crc32 manifest; a direct ``open(...,
"w")`` or ``os.rename`` under a checkpoint directory bypasses both the
crash-window guarantees and the checksums.  This rule taints names
derived from checkpoint paths (parameters/variables mentioning
``ckpt``/``checkpoint``, string literals with ``step_``/``manifest``/
``.npz``/``tmp.``/``stale``) and flags mutating filesystem calls on
tainted arguments.  ``checkpoint/store.py`` itself is exempt — it IS
the protocol.

Deliberate corruption (fault injection, crash-window tests) is expected
to carry a ``disable=`` pragma naming why.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set, Tuple

from repro.analysis.core import Finding, Project, Rule, dotted_name
from repro.analysis.callgraph import _walk_own_scope

WRITE_CALLS = {"os.rename", "os.replace", "os.remove", "os.unlink",
               "shutil.move", "shutil.rmtree", "shutil.copy",
               "shutil.copytree", "np.savez", "np.savez_compressed",
               "numpy.savez", "numpy.savez_compressed"}
PATH_TOKENS = ("ckpt", "checkpoint")
STR_TOKENS = ("step_", "manifest", ".npz", "tmp.", "stale")
EXEMPT_SUFFIX = "checkpoint/store.py"


def _token_name(name: str) -> bool:
    low = name.lower()
    return any(t in low for t in PATH_TOKENS)


def _expr_seeds_taint(expr: ast.AST, tainted: Set[str]) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.Name):
            if n.id in tainted or _token_name(n.id):
                return True
        elif isinstance(n, ast.Attribute):
            d = dotted_name(n)
            if d and (d in tainted or _token_name(n.attr)):
                return True
        elif isinstance(n, ast.Constant) and isinstance(n.value, str):
            if any(t in n.value for t in STR_TOKENS):
                return True
    return False


def _scan_scope(rule: Rule, rel: str, fn_node: ast.AST,
                params: List[str]) -> Iterable[Finding]:
    tainted: Set[str] = {p for p in params if _token_name(p)}
    assigns: List[Tuple[int, ast.AST, ast.AST]] = []
    for n in _walk_own_scope(fn_node):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                assigns.append((n.lineno, t, n.value))
        elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
            if n.value is not None:
                assigns.append((n.lineno, n.target, n.value))
    assigns.sort(key=lambda x: x[0])
    for _ in range(2):
        changed = False
        for _, target, value in assigns:
            if not _expr_seeds_taint(value, tainted):
                continue
            for t in ast.walk(target):
                d = dotted_name(t)
                if d and d not in tainted:
                    tainted.add(d)
                    changed = True
        if not changed:
            break
    for n in _walk_own_scope(fn_node):
        if not isinstance(n, ast.Call):
            continue
        d = dotted_name(n.func)
        hit = None
        if d in WRITE_CALLS and n.args:
            if any(_expr_seeds_taint(a, tainted) for a in n.args):
                hit = d
        elif (isinstance(n.func, ast.Name) and n.func.id == "open"
                and n.args and _expr_seeds_taint(n.args[0], tainted)):
            mode = ""
            if len(n.args) >= 2 and isinstance(n.args[1], ast.Constant):
                mode = str(n.args[1].value)
            for kw in n.keywords:
                if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
                    mode = str(kw.value.value)
            if any(c in mode for c in "wax+"):
                hit = f"open(..., {mode!r})"
        if hit:
            yield Finding(
                rel, n.lineno, n.col_offset, rule.id,
                f"`{hit}` touches a checkpoint path directly; route "
                f"writes through `repro.checkpoint.store` (tmp/rename "
                f"publish + crc32 manifest) so crash windows and "
                f"corruption stay recoverable")


class NonatomicCheckpointWrite(Rule):
    id = "nonatomic-checkpoint-write"
    doc = ("writes under a store path must route through the tmp/rename "
           "protocol in checkpoint/store.py")

    def run(self, project: Project) -> Iterable[Finding]:
        for f in project.files:
            if f.tree is None or f.rel.endswith(EXEMPT_SUFFIX):
                continue
            for node in ast.walk(f.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    a = node.args
                    params = [x.arg for x in
                              a.posonlyargs + a.args + a.kwonlyargs]
                    yield from _scan_scope(self, f.rel, node, params)
