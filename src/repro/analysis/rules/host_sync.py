"""host-sync-in-hot-path: no host/device synchronization on hot paths.

The paper's controller only wins if its decision overlaps worker
compute: every op between dispatch and the single scalar fetch must stay
async.  This rule takes the call graph's hot roots
(``CutoffController.observe``, ``PSServer.flush``, ``Supervisor.tick``,
every jitted body, and anything marked ``# reprolint: hot-path``),
computes reachability, and flags inside that set:

* unconditionally: ``.item()``, ``.block_until_ready()``,
  ``.copy_to_host_async()``, ``jax.device_get`` / ``jax.device_put`` —
  these ARE transfers, whatever their argument;
* conversions — ``float()`` / ``int()`` / ``bool()`` /
  ``np.asarray()`` / ``np.array()`` — only when the argument is
  *device-tainted*: derived from a ``jnp.*``/``jax.*`` call, a call to
  a jit-wrapped function, or (inside a jit body) any traced parameter.
  Host-side bookkeeping like ``int(tick)`` on the supervisor path never
  flags.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Project, Rule, dotted_name
from repro.analysis.callgraph import _walk_own_scope

UNCONDITIONAL_ATTRS = {"item", "block_until_ready", "copy_to_host_async"}
UNCONDITIONAL_CALLS = {"jax.device_get", "jax.device_put"}
CONVERSION_BUILTINS = {"float", "int", "bool"}
NUMPY_CONVERSIONS = {"asarray", "array"}


def _ref_names(expr: ast.AST) -> Set[str]:
    """Every Name / dotted-attribute chain referenced in ``expr``."""
    out: Set[str] = set()
    for n in ast.walk(expr):
        d = dotted_name(n)
        if d:
            out.add(d)
        if isinstance(n, ast.Name):
            out.add(n.id)
    return out


class _FnScanner:
    """Per-function taint pass + sync-op scan."""

    def __init__(self, rule, project, mod, info, numpy_aliases,
                 device_names, origin):
        self.rule = rule
        self.project = project
        self.mod = mod
        self.info = info
        self.numpy_aliases = numpy_aliases
        self.device_names = device_names
        self.origin = origin
        self.tainted: Set[str] = set()
        if info.is_jit:
            args = info.node.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs):
                self.tainted.add(a.arg)
            if args.vararg:
                self.tainted.add(args.vararg.arg)

    def _is_taint_source(self, node: ast.AST) -> bool:
        if not isinstance(node, ast.Call):
            return False
        d = dotted_name(node.func)
        if d is None:
            return False
        if d in UNCONDITIONAL_CALLS:        # device_get returns HOST data
            return False
        root = d.split(".")[0]
        if root in ("jnp", "jax") and "." in d:
            return True
        if d in self.device_names:
            return True
        # self.method() where the method is jitted or touches jax
        if root == "self" and d.count(".") == 1:
            cls = self.info.key[1].split(".")[0]
            attr = d.split(".")[1]
            if (cls, attr) in self.mod.jit_attrs:
                return True
            m = self.mod.funcs.get(cls + "." + attr)
            if m is not None and (m.is_jit or m.uses_jax):
                return True
        return False

    def _expr_tainted(self, expr: ast.AST) -> bool:
        if _ref_names(expr) & self.tainted:
            return True
        for n in ast.walk(expr):
            if self._is_taint_source(n):
                return True
        return False

    def _propagate(self) -> None:
        assigns: List[Tuple[int, ast.AST, ast.AST]] = []
        for n in _walk_own_scope(self.info.node):
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    assigns.append((n.lineno, t, n.value))
            elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
                if n.value is not None:
                    assigns.append((n.lineno, n.target, n.value))
        assigns.sort(key=lambda x: x[0])
        # two passes ~= fixpoint for loop-carried taint
        for _ in range(2):
            changed = False
            for _, target, value in assigns:
                if not self._expr_tainted(value):
                    continue
                for t in ast.walk(target):
                    d = dotted_name(t)
                    if d and d not in self.tainted:
                        self.tainted.add(d)
                        changed = True
            if not changed:
                break

    def scan(self) -> Iterable[Finding]:
        self._propagate()
        rel = self.info.key[0]
        where = (f"`{self.info.key[1]}` (hot via {self.origin})"
                 if self.origin != self.info.key[1]
                 else f"`{self.info.key[1]}`")
        for n in _walk_own_scope(self.info.node):
            if not isinstance(n, ast.Call):
                continue
            d = dotted_name(n.func)
            if (isinstance(n.func, ast.Attribute)
                    and n.func.attr in UNCONDITIONAL_ATTRS
                    and not n.args):
                yield Finding(
                    rel, n.lineno, n.col_offset, self.rule.id,
                    f"`.{n.func.attr}()` in {where} forces a host/device "
                    f"sync on the hot path")
                continue
            if d in UNCONDITIONAL_CALLS:
                yield Finding(
                    rel, n.lineno, n.col_offset, self.rule.id,
                    f"`{d}` in {where}: explicit transfer on the hot path")
                continue
            conv = None
            if (isinstance(n.func, ast.Name)
                    and n.func.id in CONVERSION_BUILTINS):
                conv = n.func.id
            elif (isinstance(n.func, ast.Attribute)
                    and n.func.attr in NUMPY_CONVERSIONS
                    and isinstance(n.func.value, ast.Name)
                    and n.func.value.id in self.numpy_aliases):
                conv = f"{n.func.value.id}.{n.func.attr}"
            if conv and n.args and self._expr_tainted(n.args[0]):
                yield Finding(
                    rel, n.lineno, n.col_offset, self.rule.id,
                    f"`{conv}(...)` of a device value in {where} blocks "
                    f"on the accelerator; keep it async or fetch once at "
                    f"the designated drain point")


class HostSyncInHotPath(Rule):
    id = "host-sync-in-hot-path"
    doc = ("no .item()/float()/int()/np.asarray/block_until_ready "
           "reachable from the hot roots")

    def run(self, project: Project) -> Iterable[Finding]:
        g = project.callgraph
        roots = g.hot_roots()
        # provenance: nearest root a function was first reached from
        origin: Dict[Tuple[str, str], str] = {}
        stack = []
        for r in sorted(roots):
            origin[r] = g.funcs[r].key[1]
            stack.append(r)
        while stack:
            k = stack.pop()
            for t in sorted(g.edges.get(k, ())):
                if t not in origin:
                    origin[t] = origin[k]
                    stack.append(t)
        for key in sorted(origin):
            info = g.funcs[key]
            mod = g.modules[key[0]]
            numpy_aliases = {a for a, m in mod.mod_aliases.items()
                             if m == "numpy"}
            device_names = g.device_returning_names(project, key[0])
            yield from _FnScanner(self, project, mod, info, numpy_aliases,
                                  device_names, origin[key]).scan()
