"""colwise-rng: width-shaped draws must be column-wise.

A block draw like ``jax.random.normal(key, (K, n))`` consumes the
threefry counter stream in row-major order, so the same key at width n
and padded width n_pad > n yields DIFFERENT values in the shared
columns — a padded bucket job could never reproduce its standalone
controller's samples, breaking the ragged dispatch's bit-exactness
guarantee (PR 6).  Every width-shaped draw on the decision/imputation
path must route through ``api.colwise_normal`` / ``api.colwise_uniform``
(column i a function of (key, i) alone).

Heuristic: flag raw ``jax.random.normal/uniform/truncated_normal``
calls whose shape expression references a width-like name (``n``,
``width``, ``n_workers``, ``n_pad``, ...) or ``<width-carrier>.shape``.
Draws shaped by latent dims (``(k_samples, zd)``) are allowed — they
are per-sample, not per-worker.  Scope: functions reachable from the
hot roots (the decision path) plus every jit body; model/param init is
out of scope.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional, Set

from repro.analysis.core import Finding, Project, Rule, dotted_name
from repro.analysis.callgraph import _walk_own_scope

RAW_DRAWS = {"normal", "uniform", "truncated_normal"}
WIDTH_NAMES = {"n", "width", "n_workers", "n_pad", "n_real", "n_max",
               "n_cols", "ring_width"}
WIDTH_CARRIERS = {"times", "ring", "rings", "window", "mask", "obs",
                  "x_next", "samples", "emu", "estd", "x_window", "xw"}


def _is_raw_draw(call: ast.Call, mod) -> Optional[str]:
    """The draw name if ``call`` is a raw jax.random sampler."""
    d = dotted_name(call.func)
    if d is None:
        return None
    parts = d.split(".")
    fn = parts[-1]
    if fn not in RAW_DRAWS:
        return None
    if d in (f"jax.random.{fn}",):
        return d
    # import jax.random as jr / from jax import random [as r]
    if len(parts) == 2:
        base = parts[0]
        if mod.mod_aliases.get(base) == "jax.random":
            return d
        fi = mod.from_imports.get(base)
        if fi == ("jax", "random"):
            return d
    # from jax.random import normal [as nm]
    if len(parts) == 1:
        fi = mod.from_imports.get(fn)
        if fi is not None and fi[0] == "jax.random" and fi[1] in RAW_DRAWS:
            return d
    return None


def _shape_arg(call: ast.Call) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "shape":
            return kw.value
    if len(call.args) >= 2:
        return call.args[1]
    return None


def _width_ref(shape: ast.AST) -> Optional[str]:
    for n in ast.walk(shape):
        if isinstance(n, ast.Name) and n.id in WIDTH_NAMES:
            return n.id
        if isinstance(n, ast.Attribute):
            if n.attr in WIDTH_NAMES:
                return dotted_name(n) or n.attr
            if (n.attr == "shape" and isinstance(n.value, ast.Name)
                    and n.value.id in WIDTH_CARRIERS):
                return f"{n.value.id}.shape"
    return None


class ColwiseRng(Rule):
    id = "colwise-rng"
    doc = ("decision/imputation paths draw via api.colwise_normal/"
           "colwise_uniform, never width-shaped raw jax.random.*")

    def run(self, project: Project) -> Iterable[Finding]:
        g = project.callgraph
        hot = g.reachable(g.hot_roots())
        for key in sorted(hot):
            info = g.funcs[key]
            rel = key[0]
            if rel.endswith("runtime_model/api.py"):
                continue        # the colwise implementation itself
            mod = g.modules[rel]
            for n in _walk_own_scope(info.node):
                if not isinstance(n, ast.Call):
                    continue
                draw = _is_raw_draw(n, mod)
                if draw is None:
                    continue
                shape = _shape_arg(n)
                if shape is None:
                    continue
                ref = _width_ref(shape)
                if ref is not None:
                    fn = draw.split(".")[-1]
                    yield Finding(
                        rel, n.lineno, n.col_offset, self.id,
                        f"raw `{draw}` shaped by `{ref}` in "
                        f"`{key[1]}`: width-shaped draws are not stable "
                        f"under padding — use `api.colwise_{fn}` so "
                        f"column i depends only on (key, i)")
