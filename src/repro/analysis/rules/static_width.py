"""static-argnum-width: job width must enter jits TRACED, not static.

PR 6's recompile hazard: making a per-job width (or the argmax floor
``lo``) a static argnum compiles one program per distinct width — a
mixed-width multi-tenant tick then pays J compilations and J dispatch
caches where the ragged contract promises ONE.  Widths enter as traced
operands with in-jit masks (``_batched_observe_decide_ragged`` keeps
only ``k_samples`` static).

The rule flags width-like names (``n``, ``width``, ``n_workers``,
``lo``, ``n_pad``, ...) in ``static_argnames`` literals, and resolves
``static_argnums`` indices against the decorated function's parameter
list.  The single-job fast path deliberately keeps ``lo`` static
(recompiles only on elastic resize, never per tick) — that site carries
a pragma explaining exactly that.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional

from repro.analysis.core import (Finding, Project, Rule, const_int_elems,
                                 const_str_elems, dotted_name)

WIDTH_NAMES = {"n", "width", "n_workers", "lo", "n_pad", "n_real",
               "n_max", "n_cols"}


def _jit_call(node: ast.AST) -> Optional[ast.Call]:
    """The jax.jit(...) call inside a decorator/expression, if any."""
    if not isinstance(node, ast.Call):
        return None
    d = dotted_name(node.func)
    if d in ("jax.jit", "jit"):
        return node
    if d in ("functools.partial", "partial") and node.args:
        if dotted_name(node.args[0]) in ("jax.jit", "jit"):
            return node
    return None


def _param_names(fn: ast.AST) -> List[str]:
    a = fn.args
    return [x.arg for x in a.posonlyargs + a.args]


class StaticArgnumWidth(Rule):
    id = "static-argnum-width"
    doc = "job width/lo must enter jits traced, not static"

    def run(self, project: Project) -> Iterable[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            module_fns: Dict[str, ast.AST] = {
                n.name: n for n in ast.walk(f.tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
            for node in ast.walk(f.tree):
                call = _jit_call(node)
                if call is None:
                    continue
                # the function whose params static_argnums index into
                target: Optional[ast.AST] = None
                for fn in module_fns.values():
                    if node in fn.decorator_list:
                        target = fn
                        break
                if target is None and call.args:
                    first = call.args[-1] if dotted_name(
                        call.func) in ("functools.partial",
                                       "partial") else call.args[0]
                    name = dotted_name(first)
                    if name in module_fns:
                        target = module_fns[name]
                for kw in call.keywords:
                    if kw.arg == "static_argnames":
                        names = const_str_elems(kw.value) or []
                        for s in names:
                            if s in WIDTH_NAMES:
                                yield Finding(
                                    f.rel, kw.value.lineno,
                                    kw.value.col_offset, self.id,
                                    f"static_argnames includes width-like "
                                    f"`{s}`: one compilation per distinct "
                                    f"value — pass it traced with an "
                                    f"in-jit mask (the PR 6 ragged "
                                    f"contract)")
                    elif kw.arg == "static_argnums" and target is not None:
                        idxs = const_int_elems(kw.value) or []
                        params = _param_names(target)
                        for i in idxs:
                            if 0 <= i < len(params) \
                                    and params[i] in WIDTH_NAMES:
                                yield Finding(
                                    f.rel, kw.value.lineno,
                                    kw.value.col_offset, self.id,
                                    f"static_argnums={i} pins width-like "
                                    f"parameter `{params[i]}` of "
                                    f"`{target.name}`: one compilation "
                                    f"per distinct value — pass it "
                                    f"traced with an in-jit mask")
