"""donation-after-use: a donated buffer is CONSUMED at the call site.

``jit_train_step`` donates argument 0 (the train state) so params and
optimizer moments update in place.  XLA is then free to alias the
output over the input buffer — any later read of the name that was
passed at a donated position observes garbage (or raises, backend
permitting).  The contract is rebind-and-forget:

    state, metrics = step(state, batch)      # OK: rebound same statement
    new, metrics = step(state, batch)
    loss_of(state)                           # BAD: state was donated

The pass is a per-scope, statement-ordered dataflow: donating callables
are collected first (``jax.jit(..., donate_argnums=...)`` bindings in
the same module or scope, plus ``jit_train_step(...)`` which donates
position 0 unless built with ``donate=False``), then each statement
(1) checks reads against the dead set, (2) kills names passed at
donated positions, (3) revives names (re)bound by the statement.
Findings therefore depend only on the def-use order of statements, not
their absolute positions — permuting independent statements never
changes the outcome (pinned by a hypothesis property in the tests).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, Project, Rule, const_int_elems,
                                 dotted_name)


def _donated_positions(call: ast.Call) -> Optional[Set[int]]:
    """Donated arg positions if ``call`` is ``jax.jit(...)``/``jit(...)``
    with a literal ``donate_argnums``, else None."""
    if dotted_name(call.func) not in ("jax.jit", "jit"):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            val = kw.value
            # the repo idiom: donate_argnums=(0,) if donate else ()
            if isinstance(val, ast.IfExp):
                pos = const_int_elems(val.body)
                return set(pos) if pos else set()
            pos = const_int_elems(val)
            return set(pos) if pos is not None else set()
    return set()        # jax.jit with no donation


def _is_jit_train_step(call: ast.Call) -> Optional[Set[int]]:
    """``jit_train_step(...)`` donates position 0 unless donate=False."""
    d = dotted_name(call.func)
    if d is None or d.split(".")[-1] != "jit_train_step":
        return None
    for kw in call.keywords:
        if (kw.arg == "donate"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is False):
            return set()
    return {0}


def _binding_name(target: ast.AST) -> Optional[str]:
    return dotted_name(target)


class _Scope:
    """One function (or module) body, analyzed statement by statement."""

    def __init__(self, rule: Rule, rel: str,
                 body: Sequence[ast.stmt],
                 inherited: Dict[str, Set[int]]):
        self.rule = rule
        self.rel = rel
        self.body = body
        # callable name -> donated positions
        self.donors: Dict[str, Set[int]] = dict(inherited)
        self.dead: Dict[str, Tuple[int, str]] = {}   # name -> (line, callee)
        self.findings: List[Finding] = []

    # -- helpers -------------------------------------------------------

    def _collect_donor_bindings(self) -> None:
        for stmt in self._statements():
            if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
                continue
            name = _binding_name(stmt.targets[0])
            if name is None or not isinstance(stmt.value, ast.Call):
                continue
            pos = _donated_positions(stmt.value)
            if pos is None:
                pos = _is_jit_train_step(stmt.value)
            if pos:
                self.donors[name] = pos

    def _statements(self) -> Iterable[ast.stmt]:
        """Flatten compound statements, skipping nested def/class."""
        stack: List[ast.stmt] = list(self.body)[::-1]
        while stack:
            s = stack.pop()
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                continue
            yield s
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(s, field, None)
                if sub:
                    stack.extend(reversed(sub))
            for h in getattr(s, "handlers", []) or []:
                stack.extend(reversed(h.body))

    def _donating_calls(self, stmt: ast.stmt):
        # NOTE: only calls of BOUND donor names donate.  The builder
        # calls themselves (``jax.jit(f, donate_argnums=...)``,
        # ``jit_train_step(cfg, ...)``) consume nothing — they return
        # the callable whose future calls do.
        for n in ast.walk(stmt):
            if isinstance(n, ast.Call):
                callee = dotted_name(n.func)
                if callee in self.donors:
                    yield n, callee, self.donors[callee]

    def _stores(self, stmt: ast.stmt) -> Set[str]:
        out: Set[str] = set()
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        elif isinstance(stmt, ast.For):
            targets = [stmt.target]
        elif isinstance(stmt, ast.With):
            targets = [i.optional_vars for i in stmt.items
                       if i.optional_vars is not None]
        elif isinstance(stmt, ast.Delete):
            targets = list(stmt.targets)
        for t in targets:
            for n in ast.walk(t):
                d = dotted_name(n)
                if d:
                    out.add(d)
        return out

    def _reads(self, stmt: ast.stmt) -> Iterable[Tuple[str, ast.AST]]:
        skip: Set[int] = set()
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                for n in ast.walk(t):
                    skip.add(id(n))
        for n in ast.walk(stmt):
            if id(n) in skip:
                continue
            if isinstance(n, (ast.Name, ast.Attribute)):
                if isinstance(getattr(n, "ctx", None), ast.Load):
                    d = dotted_name(n)
                    if d:
                        yield d, n

    # -- the pass ------------------------------------------------------

    def run(self) -> List[Finding]:
        self._collect_donor_bindings()
        for stmt in self._statements():
            # 1) reads of dead names
            flagged: Set[str] = set()
            for name, node in self._reads(stmt):
                hit = None
                if name in self.dead:
                    hit = name
                else:
                    # reading an attribute of a dead chain, or a dead
                    # attribute via its chain prefix
                    for dn in self.dead:
                        if name.startswith(dn + "."):
                            hit = dn
                            break
                if hit and hit not in flagged:
                    flagged.add(hit)
                    line, callee = self.dead[hit]
                    self.findings.append(Finding(
                        self.rel, node.lineno, node.col_offset, self.rule.id,
                        f"`{name}` is read after being donated to "
                        f"`{callee}` on line {line}; a donated buffer may "
                        f"be aliased by its output — rebind the result "
                        f"and drop the old name"))
            # 2) kills: names at donated positions
            for call, callee, positions in self._donating_calls(stmt):
                for i in positions:
                    if i < len(call.args):
                        d = dotted_name(call.args[i])
                        if d:
                            self.dead[d] = (call.lineno, callee)
            # 3) revives: (re)bindings
            for name in self._stores(stmt):
                self.dead.pop(name, None)
                stale = [k for k in self.dead if k.startswith(name + ".")]
                for k in stale:
                    self.dead.pop(k)
        return self.findings


class DonationAfterUse(Rule):
    id = "donation-after-use"
    doc = ("a name passed at a donate_argnums position may not be read "
           "afterwards in the same scope")

    def run(self, project: Project) -> Iterable[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            module_scope = _Scope(self, f.rel, f.tree.body, {})
            yield from module_scope.run()
            module_donors = module_scope.donors
            for node in ast.walk(f.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield from _Scope(self, f.rel, node.body,
                                      module_donors).run()
