"""twin-epsilon-drift: numeric guards shared across backend twins.

The cutoff math ships as pairs — a float64 numpy reference and an f32
jax twin (``throughput_curve`` / ``throughput_curve_jax``,
``truncated_normal_sample`` / ``truncated_normal_sample_jax``, ...) —
that must produce IDENTICAL seeded cutoff sequences.  A clip or epsilon
constant typed inline in one twin ("1e-9" here, "1e-8" there after a
refactor) silently splits the two distributions; the parity suites only
catch it when a seed happens to land inside the gap.

The rule finds module-level ``f`` / ``f_jax`` pairs and flags any
inline float literal with 0 < |v| < 1e-3 in either body: epsilons in
twins must be hoisted to a shared, backend-neutral named constant
(``core/cutoff/eps.py``) that both read.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Tuple

from repro.analysis.core import Finding, Project, Rule
from repro.analysis.callgraph import _walk_own_scope

JAX_SUFFIX = "_jax"
EPS_MAX = 1e-3


class TwinEpsilonDrift(Rule):
    id = "twin-epsilon-drift"
    doc = ("clip/epsilon constants in f/f_jax backend twins must be "
           "shared named constants, not inline literals")

    def run(self, project: Project) -> Iterable[Finding]:
        for f in project.files:
            if f.tree is None:
                continue
            fns: Dict[str, ast.AST] = {}
            for node in ast.walk(f.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    fns.setdefault(node.name, node)
            twins: List[Tuple[str, ast.AST]] = []
            for name, node in fns.items():
                if name.endswith(JAX_SUFFIX):
                    base = name[:-len(JAX_SUFFIX)]
                    if base in fns:
                        twins.append((name, node))
                        twins.append((base, fns[base]))
            for name, node in sorted(twins, key=lambda t: t[1].lineno):
                for n in _walk_own_scope(node):
                    if not (isinstance(n, ast.Constant)
                            and isinstance(n.value, float)):
                        continue
                    v = abs(n.value)
                    if 0.0 < v < EPS_MAX:
                        yield Finding(
                            f.rel, n.lineno, n.col_offset, self.id,
                            f"inline epsilon {n.value!r} in backend twin "
                            f"`{name}`: hoist it to a shared named "
                            f"constant both twins read "
                            f"(core/cutoff/eps.py) so the f64 and f32 "
                            f"paths can never drift apart")
