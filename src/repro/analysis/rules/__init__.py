"""Rule registry for reprolint.

Each rule module exports one :class:`repro.analysis.core.Rule` subclass;
``all_rules()`` instantiates the full set in catalog order and
``rule_ids()`` is the vocabulary valid in ``disable=`` pragmas.
"""
from __future__ import annotations

from typing import List, Set

from repro.analysis.core import BAD_SUPPRESSION, PARSE_ERROR, Rule
from repro.analysis.rules.host_sync import HostSyncInHotPath
from repro.analysis.rules.donation import DonationAfterUse
from repro.analysis.rules.colwise_rng import ColwiseRng
from repro.analysis.rules.checkpoint_write import NonatomicCheckpointWrite
from repro.analysis.rules.event_kinds import EventKindDrift
from repro.analysis.rules.static_width import StaticArgnumWidth
from repro.analysis.rules.twin_epsilon import TwinEpsilonDrift

RULE_CLASSES = (HostSyncInHotPath, DonationAfterUse, ColwiseRng,
                NonatomicCheckpointWrite, EventKindDrift,
                StaticArgnumWidth, TwinEpsilonDrift)


def all_rules() -> List[Rule]:
    return [cls() for cls in RULE_CLASSES]


def rule_ids() -> Set[str]:
    return ({cls.id for cls in RULE_CLASSES}
            | {BAD_SUPPRESSION, PARSE_ERROR})
