"""CLI: ``python -m repro.analysis [paths...] [options]``.

Exit codes: 0 clean (or advisory mode), 1 findings under ``--strict``
(or a failed audit), 2 usage errors.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: contract linter + jaxpr auditor")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src tests)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any finding (CI mode; default is "
                         "report-only)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE", help="run only these rule ids")
    ap.add_argument("--audit", action="store_true",
                    help="run the jaxpr audit instead of linting")
    ap.add_argument("--out", default="ANALYSIS.json",
                    help="audit report path (with --audit)")
    args = ap.parse_args(argv)

    if args.audit:
        from repro.analysis.jaxpr_audit import write_report

        report = write_report(args.out)
        for e in report["entries"]:
            ok = e["transfer_free"] and e["donation"]["effective"]
            status = "ok" if ok else "FAIL"
            print(f"audit {status}: {e['name']}: {e['n_eqns']} eqns, "
                  f"forbidden={e['forbidden_primitives']}, "
                  f"aliased_outputs="
                  f"{e['donation']['n_aliased_outputs']}")
        print(f"wrote {args.out}")
        return 0 if report["ok"] else 1

    from repro.analysis import all_rules, lint_paths, render_json, \
        render_text, rule_ids

    rules = all_rules()
    if args.select:
        known = rule_ids()
        bad = [r for r in args.select if r not in known]
        if bad:
            print(f"unknown rule(s): {', '.join(bad)}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in set(args.select)]
    paths = args.paths or ["src", "tests"]
    findings = lint_paths(paths, rules=rules)
    if args.format == "json":
        sys.stdout.write(render_json(findings, {"paths": paths}))
    else:
        print(render_text(findings))
    if findings and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
