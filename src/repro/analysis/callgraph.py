"""Lightweight intraprocedural call graph over a lint project.

Good enough to answer ONE question: which functions are reachable from
the hot roots (``CutoffController.observe``, ``PSServer.flush``,
``Supervisor.tick``, anything jitted, anything marked
``# reprolint: hot-path``)?  Resolution is conservative — a call that
cannot be resolved simply adds no edge — so reachability
under-approximates and the host-sync rule never flags code it cannot
prove hot.

Resolved call forms: bare names (nested defs first, then module scope,
then from-imports), ``self.method`` (own class, then single-level bases
defined in the same file), and ``alias.attr`` where ``alias`` is an
imported module that is part of the project.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Project, SourceFile, dotted_name

#: (class, method) pairs that are hot roots by contract, wherever they
#: are defined (so lint fixtures can declare them too).
HOT_METHODS = {("CutoffController", "observe"),
               ("PSServer", "flush"),
               ("Supervisor", "tick")}

FuncKey = Tuple[str, str]          # (file rel, qualname)


@dataclass
class FuncInfo:
    key: FuncKey
    node: ast.AST                  # FunctionDef / AsyncFunctionDef
    lineno: int
    is_jit: bool = False           # body runs under jax.jit tracing
    is_hot_root: bool = False
    uses_jax: bool = False         # touches jax/jnp -> result smells device
    calls: List[ast.Call] = field(default_factory=list)


@dataclass
class _ModuleIndex:
    file: SourceFile
    funcs: Dict[str, FuncInfo] = field(default_factory=dict)
    # local name -> dotted module ('np' -> 'numpy')
    mod_aliases: Dict[str, str] = field(default_factory=dict)
    # local name -> (dotted module, attr)  (from-imports)
    from_imports: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    bases: Dict[str, List[str]] = field(default_factory=dict)
    # class attr assigned a jit: ('Cls', '_decode') from
    # ``self._decode = jax.jit(...)``
    jit_attrs: Set[Tuple[str, str]] = field(default_factory=set)


def _is_jit_expr(node: ast.AST) -> Optional[str]:
    """If ``node`` is ``jax.jit(f, ...)`` / ``jit(f, ...)`` /
    ``partial(jax.jit, ...)`` applied to a bare name, return that name."""
    if not isinstance(node, ast.Call):
        return None
    fn = dotted_name(node.func)
    if fn in ("jax.jit", "jit") and node.args:
        inner = node.args[0]
        if isinstance(inner, ast.Name):
            return inner.id
    return None


def _decorator_is_jit(dec: ast.AST) -> bool:
    name = dotted_name(dec)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(dec, ast.Call):
        fn = dotted_name(dec.func)
        if fn in ("jax.jit", "jit"):
            return True
        if fn in ("functools.partial", "partial") and dec.args:
            return dotted_name(dec.args[0]) in ("jax.jit", "jit")
    return False


def _walk_own_scope(fn_node: ast.AST):
    """Walk a function body without descending into nested def/class
    scopes; lambda bodies DO belong to the enclosing scope."""
    stack = list(ast.iter_child_nodes(fn_node))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


class CallGraph:
    def __init__(self):
        self.modules: Dict[str, _ModuleIndex] = {}
        self.funcs: Dict[FuncKey, FuncInfo] = {}
        self.edges: Dict[FuncKey, Set[FuncKey]] = {}

    # -- construction -------------------------------------------------

    @classmethod
    def build(cls, project: Project) -> "CallGraph":
        g = cls()
        for f in project.files:
            if f.tree is None:
                continue
            g.modules[f.rel] = g._index_module(f)
        for rel, mod in g.modules.items():
            for info in mod.funcs.values():
                g.funcs[info.key] = info
        for rel, mod in g.modules.items():
            g._resolve_module(project, mod)
        return g

    def _index_module(self, f: SourceFile) -> _ModuleIndex:
        mod = _ModuleIndex(file=f)
        jit_names: Set[str] = set()

        def collect_fn(node, qual_prefix, cls_name):
            qual = (qual_prefix + "." if qual_prefix else "") + node.name
            info = FuncInfo(key=(f.rel, qual), node=node, lineno=node.lineno)
            info.is_jit = any(_decorator_is_jit(d)
                              for d in node.decorator_list)
            if cls_name and (cls_name, node.name) in HOT_METHODS:
                info.is_hot_root = True
            marker_lines = {node.lineno, node.lineno - 1}
            if node.decorator_list:
                marker_lines.add(node.decorator_list[0].lineno - 1)
            if marker_lines & f.hot_path_lines:
                info.is_hot_root = True
            mod.funcs[qual] = info
            for sub in _walk_own_scope(node):
                if isinstance(sub, ast.Call):
                    info.calls.append(sub)
                    jn = _is_jit_expr(sub)
                    if jn:
                        jit_names.add(jn)
                name = dotted_name(sub)
                if name and (name == "jax" or name.startswith("jax.")
                             or name == "jnp" or name.startswith("jnp.")):
                    info.uses_jax = True
            # nested defs: own scopes, resolvable as '<outer>.<name>'
            for sub in node.body:
                _walk_defs(sub, qual, cls_name)
            # class-attr jits: self._x = jax.jit(...)
            if cls_name:
                for sub in ast.walk(node):
                    if (isinstance(sub, ast.Assign)
                            and len(sub.targets) == 1
                            and isinstance(sub.targets[0], ast.Attribute)
                            and _is_jit_expr(sub.value) is not None):
                        tgt = sub.targets[0]
                        if (isinstance(tgt.value, ast.Name)
                                and tgt.value.id == "self"):
                            mod.jit_attrs.add((cls_name, tgt.attr))

        def _walk_defs(node, qual_prefix, cls_name):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                collect_fn(node, qual_prefix, cls_name)
            elif isinstance(node, ast.ClassDef):
                mod.bases[node.name] = [
                    b for b in (dotted_name(x) for x in node.bases) if b]
                for sub in node.body:
                    _walk_defs(sub, node.name, node.name)
            elif isinstance(node, (ast.If, ast.Try, ast.With)):
                for sub in ast.iter_child_nodes(node):
                    _walk_defs(sub, qual_prefix, cls_name)

        tree = f.tree
        for node in tree.body:
            _walk_defs(node, "", None)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.mod_aliases[a.asname or a.name.split(".")[0]] = \
                        a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    mod.from_imports[a.asname or a.name] = (node.module,
                                                            a.name)
            elif isinstance(node, ast.Assign):
                jn = _is_jit_expr(node.value)
                if jn and jn in mod.funcs:
                    mod.funcs[jn].is_jit = True
        for name in jit_names:
            for qual, info in mod.funcs.items():
                if qual == name or qual.endswith("." + name):
                    info.is_jit = True
        return mod

    def _resolve_module(self, project: Project, mod: _ModuleIndex) -> None:
        for qual, info in mod.funcs.items():
            targets: Set[FuncKey] = set()
            for call in info.calls:
                t = self._resolve_call(project, mod, qual, call)
                if t is not None:
                    targets.add(t)
            self.edges[info.key] = targets

    def _resolve_call(self, project: Project, mod: _ModuleIndex,
                      caller_qual: str, call: ast.Call) -> Optional[FuncKey]:
        func = call.func
        # bare name: nested def of the caller, then module scope, then
        # a from-import into a project module
        if isinstance(func, ast.Name):
            name = func.id
            nested = caller_qual + "." + name
            if nested in mod.funcs:
                return mod.funcs[nested].key
            if name in mod.funcs:
                return mod.funcs[name].key
            if name in mod.from_imports:
                target_mod, attr = mod.from_imports[name]
                return self._lookup(project, target_mod, attr)
            return None
        if isinstance(func, ast.Attribute):
            base = func.value
            # self.method()
            if isinstance(base, ast.Name) and base.id == "self":
                cls = caller_qual.split(".")[0]
                for c in [cls] + mod.bases.get(cls, []):
                    q = c + "." + func.attr
                    if q in mod.funcs:
                        return mod.funcs[q].key
                return None
            # module_alias.func()
            name = dotted_name(base)
            if name is None:
                return None
            target_mod = mod.mod_aliases.get(name)
            if target_mod is None and name in mod.from_imports:
                m, attr = mod.from_imports[name]
                target_mod = m + "." + attr     # from pkg import module
            if target_mod is not None:
                return self._lookup(project, target_mod, func.attr)
        return None

    def _lookup(self, project: Project, module: str,
                attr: str) -> Optional[FuncKey]:
        f = project.modules.get(module)
        if f is None or f.rel not in self.modules:
            return None
        funcs = self.modules[f.rel].funcs
        if attr in funcs:
            return funcs[attr].key
        return None

    # -- queries ------------------------------------------------------

    def hot_roots(self) -> Set[FuncKey]:
        return {k for k, i in self.funcs.items()
                if i.is_jit or i.is_hot_root}

    def jit_keys(self) -> Set[FuncKey]:
        return {k for k, i in self.funcs.items() if i.is_jit}

    def reachable(self, roots: Set[FuncKey]) -> Set[FuncKey]:
        seen = set(roots)
        stack = list(roots)
        while stack:
            k = stack.pop()
            for t in self.edges.get(k, ()):
                if t not in seen:
                    seen.add(t)
                    stack.append(t)
        return seen

    def device_returning_names(self, project: Project,
                               rel: str) -> Set[str]:
        """Names usable in module ``rel`` whose call result smells
        device-resident: jit-wrapped functions, plus any project
        function that itself touches jax/jnp (heuristic used by the
        host-sync taint pass)."""
        mod = self.modules.get(rel)
        if mod is None:
            return set()
        out: Set[str] = set()
        for qual, info in mod.funcs.items():
            if info.is_jit or info.uses_jax:
                out.add(qual.split(".")[-1])
        for name, (m, attr) in mod.from_imports.items():
            key = self._lookup(project, m, attr)
            if key is not None:
                info = self.funcs[key]
                if info.is_jit or info.uses_jax:
                    out.add(name)
        return out
