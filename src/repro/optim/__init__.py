from repro.optim.optimizers import (
    Optimizer, adam, adamw, apply_updates, chain, clip_by_global_norm,
    global_norm, momentum, sgd,
)
from repro.optim.schedules import constant, cosine_schedule, linear_warmup
from repro.optim.compression import (
    compress_int8, decompress_int8, error_feedback_compress,
)

__all__ = [
    "Optimizer", "adam", "adamw", "apply_updates", "chain",
    "clip_by_global_norm", "global_norm", "momentum", "sgd",
    "constant", "cosine_schedule", "linear_warmup",
    "compress_int8", "decompress_int8", "error_feedback_compress",
]
