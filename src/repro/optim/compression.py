"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 per-tensor-scale quantization with error feedback: the residual of each
quantization step is carried and added to the next gradient, so compression
error does not accumulate (Seide et al. / 1-bit-SGD style EF).  Intended for
the "pod" axis where DCN bandwidth, not ICI, is the bottleneck.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """x fp -> (int8 codes, fp32 scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def error_feedback_compress(grads, residuals):
    """Quantize grads+residuals; return (quantized fp grads, new residuals).

    The returned grads are the dequantized values (what the wire carries);
    residuals hold the per-leaf quantization error for the next step.
    """
    if residuals is None:
        residuals = jax.tree.map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)

    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, s = compress_int8(tot)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), tot - deq

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in out])
    new_r = jax.tree.unflatten(tree, [o[1] for o in out])
    return new_g, new_r
