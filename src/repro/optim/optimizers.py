"""Pytree optimizers built from scratch (no optax in this environment).

Minimal composable design: an ``Optimizer`` is (init, update); ``update``
maps (grads, state, params) -> (updates, state) where updates are *added* to
params (learning rate already folded in, sign flipped).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates)


# ---------------------------------------------------------------------------


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"]
        lr_t = _lr_at(lr, step)
        ups = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return ups, {"step": step + 1}

    return Optimizer(init, update)


def momentum(lr: Schedule, beta: float = 0.9, nesterov: bool = False
             ) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros_like(
                    p, dtype=jnp.float32), params)}

    def update(grads, state, params=None):
        step = state["step"]
        lr_t = _lr_at(lr, step)
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                          state["mu"], grads)
        if nesterov:
            ups = jax.tree.map(
                lambda m, g: -lr_t * (beta * m + g.astype(jnp.float32)),
                mu, grads)
        else:
            ups = jax.tree.map(lambda m: -lr_t * m, mu)
        return ups, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0, *,
         fused: bool = False) -> Optimizer:
    """Adam(W).  ``fused=True`` routes the whole moment-and-param update
    through ``kernels.ops.adam_update_tree`` — the Pallas one-HBM-pass
    kernel on TPU, with the pure-jnp reference under the default ``"xla"``
    kernel backend.  Matches the unfused path allclose (the fused kernel
    computes p' directly, so the returned "update" is p' - p up to one
    rounding)."""
    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def fused_update(grads, state, params):
        from repro.kernels import ops
        if params is None:
            raise ValueError("adam(fused=True) needs params at update time")
        lr_t = _lr_at(lr, state["step"])
        p_new, m, v = ops.adam_update_tree(
            params, grads, state["m"], state["v"], state["step"], lr_t,
            b1=b1, b2=b2, eps=eps, wd=weight_decay)
        ups = jax.tree.map(
            lambda pn, p: pn.astype(jnp.float32) - p.astype(jnp.float32),
            p_new, params)
        return ups, {"step": state["step"] + 1, "m": m, "v": v}

    def update(grads, state, params=None):
        if fused:
            return fused_update(grads, state, params)
        step = state["step"] + 1
        lr_t = _lr_at(lr, state["step"])
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -(lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay:
            ups = jax.tree.map(upd, m, v, params)
        else:
            ups = jax.tree.map(lambda m_, v_: upd(m_, v_, None), m, v)
        return ups, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01, *,
          fused: bool = False) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay, fused=fused)


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params=None):
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)


def chain(*fns):
    """Compose gradient-mapping callables before an optimizer's update."""
    *pre, opt = fns

    def update(grads, state, params=None):
        for f in pre:
            grads = f(grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)
