"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(v: float):
    return lambda step: jnp.asarray(v, jnp.float32)


def linear_warmup(base: float, warmup_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return base * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
    return fn


def cosine_schedule(base: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = base * jnp.minimum(1.0, (s + 1) / max(warmup_steps, 1))
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return warm * jnp.where(s < warmup_steps, 1.0, cos)
    return fn
