"""jit'd public wrappers around the Pallas kernels.

``KERNEL_BACKEND`` picks the execution path:
  * "pallas"    — real TPU lowering (production)
  * "interpret" — Pallas interpret mode (CPU validation; used by tests)
  * "xla"       — the pure-jnp reference (this container's default runtime)

The model stack calls these wrappers so the TPU deployment flips one flag.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_adam as _ad
from repro.kernels import masked_grad_agg as _ma
from repro.kernels import mlstm_chunk as _ml
from repro.kernels import ref

KERNEL_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "xla")


def _mode():
    return KERNEL_BACKEND


def attention(q, k, v, *, causal=True, window=0):
    m = _mode()
    if m == "xla":
        return ref.reference_attention(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=(m == "interpret"))


def mlstm(q, k, v, g, i, *, chunk=128):
    m = _mode()
    if m == "xla":
        return ref.reference_mlstm(q, k, v, g, i)
    return _ml.mlstm_chunk(q, k, v, g, i, chunk=chunk,
                           interpret=(m == "interpret"))


def _pad_to(x, r, c):
    n = x.size
    cols = c
    rows = -(-n // cols)
    rows = -(-rows // r) * r
    pad = rows * cols - n
    return jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, cols), n


def adam_update_tree(params, grads, m, v, step, lr, *, b1=0.9, b2=0.999,
                     eps=1e-8, wd=0.0):
    """Apply the fused Adam kernel leaf-wise over a pytree."""
    mode = _mode()
    t = step.astype(jnp.float32) + 1.0
    scalars = jnp.stack([jnp.asarray(lr, jnp.float32),
                         1.0 - b1 ** t, 1.0 - b2 ** t])

    def one(p, g, m_, v_):
        if mode == "xla":
            return ref.reference_adam(p.reshape(1, -1), g.reshape(1, -1),
                                      m_.reshape(1, -1), v_.reshape(1, -1),
                                      scalars, b1=b1, b2=b2, eps=eps, wd=wd)
        pp, n = _pad_to(p, 8, 128)
        gg, _ = _pad_to(g, 8, 128)
        mm, _ = _pad_to(m_, 8, 128)
        vv, _ = _pad_to(v_, 8, 128)
        po, mo, vo = _ad.fused_adam(pp, gg, mm, vv, scalars, b1=b1, b2=b2,
                                    eps=eps, wd=wd,
                                    interpret=(mode == "interpret"))
        cut = lambda x: x.reshape(-1)[:n].reshape(p.shape)
        return cut(po), cut(mo), cut(vo)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(m)
    flat_v = jax.tree.leaves(v)
    outs = [one(p, g, m_, v_)
            for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    unf = lambda i: jax.tree.unflatten(tree, [o[i].reshape(p.shape)
                                              for o, p in zip(outs, flat_p)])
    return unf(0), unf(1), unf(2)


def masked_aggregate(grads_stacked, mask, *, block: int = 2048):
    """grads_stacked: (W, N); mask: (W,) -> (N,) cutoff-weighted mean.

    Pads N up to the kernel's lane contract: a multiple of 128 when one
    block covers it, a multiple of ``block`` when the grid tiles it (the
    kernel requires the block size to divide the padded N).
    """
    m = _mode()
    mask2 = mask.reshape(-1, 1)
    if m == "xla":
        return ref.reference_masked_agg(grads_stacked, mask2)[0]
    assert block % 128 == 0, block   # the kernel's lane contract
    W, N = grads_stacked.shape
    tile = block if N > block else 128
    pad = (-N) % tile
    gp = jnp.pad(grads_stacked, ((0, 0), (0, pad)))
    out = _ma.masked_grad_agg(gp, mask2, block=block,
                              interpret=(m == "interpret"))
    return out[0, :N]


def masked_aggregate_tree(grads, mask, *, block: int = 2048):
    """Masked mean over the leading worker dim of a gradient pytree.

    The host-side stacked combine behind ``dist.collectives`` when no mesh
    is active: every leaf (W, ...) is flattened to (W, n) and concatenated
    into one (W, N) buffer so the whole tree is a single fused HBM pass of
    the masked_grad_agg kernel (fp32 accumulation, padded to the 128-lane
    contract), then split and cast back per leaf.  Under the "xla" backend
    it is the pure-jnp reference (``aggregation.masked_mean_local``), which
    keeps each leaf in its own dtype.
    """
    if _mode() == "xla":
        from repro.core import aggregation
        return aggregation.masked_mean_local(grads, mask)
    flat, tree = jax.tree.flatten(grads)
    W = flat[0].shape[0]
    buf = jnp.concatenate(
        [l.reshape(W, -1).astype(jnp.float32) for l in flat], axis=1)
    out = masked_aggregate(buf, jnp.asarray(mask, jnp.float32), block=block)
    outs, off = [], 0
    for l in flat:
        n = l.size // W
        outs.append(out[off:off + n].reshape(l.shape[1:]).astype(l.dtype))
        off += n
    return jax.tree.unflatten(tree, outs)
