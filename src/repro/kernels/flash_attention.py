"""Pallas TPU flash attention (blocked online softmax).

TARGET: TPU MXU/VMEM.  Grid (B, H, Sq/bq, Sk/bk) with the last dim
sequential ("arbitrary"); running (m, l, acc) live in VMEM scratch and the
output tile is written once on the final K block — the K/V stream never
materializes an (Sq, Sk) score matrix in HBM.

GQA is handled in the BlockSpec index maps (query head h reads KV head
h // group).  Causal + sliding-window masking is applied with block-local
iota against global offsets; fully-masked blocks are skipped via pl.when
(grid-level skipping needs per-row KV bounds — a noted production follow-up).

VALIDATED in interpret mode on CPU against ``ref.reference_attention``
(= the model stack's attn_core contract) — see tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level skip test (static per (iq, ik) given causal/window)
    q_hi = q_start + block_q - 1
    k_lo = k_start
    run = True
    if causal:
        run = k_lo <= q_hi
    # window lower bound: newest query row still sees k >= q_hi - window + 1
    # (older rows see earlier k; block partially masked, handled by the mask)

    def body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    if causal:
        pl.when(k_lo <= q_hi)(body)
    else:
        body()

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) -> (B, Sq, H, hd).

    Self-attention with aligned positions (q row i is global position
    i + Sk - Sq); covers the train/prefill layouts used by the model stack.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_k = Sk // bk
    scale = 1.0 / math.sqrt(hd)

    grid = (B, H, Sq // bq, n_k)
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, block_q=bq, block_k=bk, n_k=n_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
