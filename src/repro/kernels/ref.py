"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal=True, window=0):
    """Dense attention, the contract of kernels.flash_attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd).  Query row i sits at global
    position i + Sk - Sq (aligned suffixes).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", a, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def reference_mlstm(q, k, v, g, i):
    """Sequential stabilized mLSTM recurrence (the mlstm_chunk contract).

    q/k/v: (B, S, H, hd); g/i: (B, S, H) log forget/input gates -> fp32 out.
    """
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, gt, it = xs
        m_new = jnp.maximum(gt + m, it)
        fp = jnp.exp(gt + m - m_new)[..., None, None]
        ip = jnp.exp(it - m_new)[..., None, None]
        C = fp * C + ip * (kt[..., :, None] * vt[..., None, :])
        n = fp[..., 0] * n + ip[..., 0] * kt
        num = jnp.einsum("bhq,bhqv->bhv", qt, C) * scale
        den = jnp.einsum("bhq,bhq->bh", qt, n) * scale
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    z = jnp.zeros((B, H, hd, hd), jnp.float32)
    zn = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (q, k, v, g, i))
    _, ys = jax.lax.scan(step, (z, zn, m0), xs)
    return jnp.moveaxis(ys, 0, 1)


def reference_adam(p, g, m, v, scalars, *, b1=0.9, b2=0.999, eps=1e-8,
                   wd=0.0):
    lr, bc1, bc2 = scalars[0], scalars[1], scalars[2]
    gf = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * gf
    v_new = b2 * v + (1 - b2) * gf * gf
    up = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if wd:
        up = up + wd * p.astype(jnp.float32)
    return ((p.astype(jnp.float32) - lr * up).astype(p.dtype), m_new, v_new)


def reference_masked_agg(grads, mask):
    m = mask.astype(jnp.float32)
    c = jnp.maximum(jnp.sum(m), 1.0)
    return (jnp.sum(grads.astype(jnp.float32) * m, axis=0, keepdims=True)
            / c).astype(grads.dtype)
