"""Pallas TPU fused Adam(W) update.

TARGET: TPU VPU.  The optimizer update is bandwidth-bound: p, g, m, v are
read and p', m', v' written — 7 streams.  Unfused XLA emits each arithmetic
op as a separate HBM round-trip unless fusion catches everything; the kernel
guarantees one pass, tiled (8, 128)-aligned in VMEM.

ops.py exposes ``adam_update_tree`` which flattens a pytree, pads to tile
size, and applies the kernel leaf-wise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(p_ref, g_ref, m_ref, v_ref, s_ref, po_ref, mo_ref, vo_ref, *,
            b1: float, b2: float, eps: float, wd: float):
    lr = s_ref[0]
    bc1 = s_ref[1]   # 1 - b1**t
    bc2 = s_ref[2]   # 1 - b2**t
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    p = p_ref[...].astype(jnp.float32)
    up = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    if wd:
        up = up + wd * p
    po_ref[...] = (p - lr * up).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd",
                                             "block", "interpret"))
def fused_adam(p, g, m, v, scalars, *, b1=0.9, b2=0.999, eps=1e-8, wd=0.0,
               block: int = 1024, interpret: bool = False):
    """p/g: (R, C); m/v: (R, C) fp32; scalars: (3,) [lr, 1-b1^t, 1-b2^t].

    Returns (p', m', v').  R*C should be padded to (8k, 128m) tiles by the
    ops.py wrapper.
    """
    R, C = p.shape
    br = min(8, R)
    bc = min(block, C)
    assert R % br == 0 and C % bc == 0
    grid = (R // br, C // bc)
    kern = functools.partial(_kernel, b1=b1, b2=b2, eps=eps, wd=wd)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    sspec = pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec, spec, spec, spec, sspec],
        out_specs=(spec, spec, spec),
        out_shape=(jax.ShapeDtypeStruct(p.shape, p.dtype),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32),
                   jax.ShapeDtypeStruct(p.shape, jnp.float32)),
        interpret=interpret,
    )(p, g, m, v, scalars)
