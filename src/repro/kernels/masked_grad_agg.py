"""Pallas TPU masked gradient aggregation (the cutoff combine, paper §4.3).

TARGET: TPU VPU.  On a host aggregating W virtual-worker sub-gradients
(stacked (W, N)), the cutoff update is sum_w bit_w * g_w / sum(bit) — a
bandwidth-bound weighted reduction.  The kernel fuses mask-scale-accumulate
in one HBM pass over the stacked buffer; the result feeds the bit-array ring
all-reduce across hosts.

Callers go through ``kernels.ops.masked_aggregate`` /
``masked_aggregate_tree``, which flatten arbitrary gradient pytrees into
the (W, N) contract and pad N so the block size divides it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(g_ref, mask_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)             # (W, bc)
    m = mask_ref[...].astype(jnp.float32)          # (W, 1) in SMEM-ish VMEM
    c = jnp.maximum(jnp.sum(m), 1.0)
    o_ref[...] = (jnp.sum(g * m, axis=0, keepdims=True) / c
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def masked_grad_agg(grads, mask, *, block: int = 2048,
                    interpret: bool = False):
    """grads: (W, N); mask: (W, 1) float -> (1, N) masked mean over workers.

    N must be a multiple of 128 (ops.py pads).
    """
    W, N = grads.shape
    assert mask.shape == (W, 1), mask.shape
    bc = min(block, N)
    assert N % bc == 0, (N, bc)
    return pl.pallas_call(
        _kernel,
        grid=(N // bc,),
        in_specs=[pl.BlockSpec((W, bc), lambda j: (0, j)),
                  pl.BlockSpec((W, 1), lambda j: (0, 0))],
        out_specs=pl.BlockSpec((1, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, N), grads.dtype),
        interpret=interpret,
    )(grads, mask)
