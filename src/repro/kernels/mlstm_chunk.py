"""Pallas TPU chunkwise mLSTM (matrix-memory linear attention, exp gating).

TARGET: TPU.  Grid (B, H, n_chunks) with the chunk dim sequential
("arbitrary"); the (hd x hd) matrix memory C, normalizer n and stabilizer m
are carried across chunks in VMEM scratch and NEVER round-trip to HBM — the
hardware-adaptation of GPU recurrent kernels (DESIGN.md): intra-chunk math
is two MXU matmuls (q k^T and p v), inter-chunk state is a VMEM-resident
rank-hd update.

Matches ``repro.models.ssm.linear_recurrence(..., normalize=True)`` (the
pure-jnp oracle in ref.py) for scale = 1/sqrt(hd).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, g_ref, i_ref, o_ref, c_scr, n_scr, m_scr,
            *, chunk: int, hd: int, scale: float):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (c, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    g = g_ref[0, :, 0].astype(jnp.float32)              # (c,) log decay
    ig = i_ref[0, :, 0].astype(jnp.float32)             # (c,) log input gate

    lg = jnp.cumsum(g)                                  # within-chunk decay
    tot = lg[-1]
    m_prev = m_scr[0]
    # intra-chunk log-weight matrix D[t,s] = lg_t - lg_s + i_s  (s <= t)
    D = lg[:, None] - lg[None, :] + ig[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = jnp.where(tri, D, NEG)
    m_intra = jnp.max(D, axis=1)
    lg_e = lg + m_prev
    m_out = jnp.maximum(lg_e, m_intra)                  # (c,)

    W = jnp.exp(D - m_out[:, None])
    dot = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    WS = W * dot
    num = jax.lax.dot_general(WS, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    den = jnp.sum(WS, axis=1)
    sc_e = jnp.exp(lg_e - m_out)
    num += sc_e[:, None] * jax.lax.dot_general(
        q, c_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    den += sc_e * jnp.sum(q * n_scr[...][None, :] if False else
                          q * jnp.broadcast_to(n_scr[...], q.shape), axis=1)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_out))
    o_ref[0, :, 0, :] = (num / den[:, None]).astype(o_ref.dtype)

    # ---- state update (chunk contribution at the chunk end) ----
    w_s = tot - lg + ig                                 # carry-to-end weight
    m_loc = jnp.max(w_s)
    m_new = jnp.maximum(m_prev + tot, m_loc)
    sc = jnp.exp(w_s - m_new)
    kc = k * sc[:, None]
    c_new = (c_scr[...] * jnp.exp(m_prev + tot - m_new)
             + jax.lax.dot_general(kc, v, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    n_new = (n_scr[...] * jnp.exp(m_prev + tot - m_new)
             + jnp.sum(kc, axis=0))
    c_scr[...] = c_new
    n_scr[...] = n_new
    m_scr[0] = m_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, g, i, *, chunk: int = 128, interpret: bool = False):
    """q/k/v: (B, S, H, hd); g/i: (B, S, H) log gates -> y (B, S, H, hd) f32.

    Output matches the stabilized normalized recurrence
    h_t = (q_t . C_t) / max(|q_t . n_t|, exp(-m_t)) with C/n/m carried across
    chunks in VMEM.
    """
    B, S, H, hd = q.shape
    c = min(chunk, S)
    assert S % c == 0
    n_chunks = S // c
    scale = 1.0 / math.sqrt(hd)
    kern = functools.partial(_kernel, chunk=c, hd=hd, scale=scale)

    qspec = pl.BlockSpec((1, c, 1, hd), lambda b, h, ic: (b, ic, h, 0))
    gspec = pl.BlockSpec((1, c, 1), lambda b, h, ic: (b, ic, h))
    return pl.pallas_call(
        kern,
        grid=(B, H, n_chunks),
        in_specs=[qspec, qspec, qspec, gspec, gspec],
        out_specs=pl.BlockSpec((1, c, 1, hd), lambda b, h, ic: (b, ic, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((hd,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, g, i)
